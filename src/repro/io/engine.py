"""CodingEngine: an op queue with cross-request batched execution.

Callers (the `StripeCodec` planner, and through it the `RequestFrontend`)
submit op descriptors — read, decode-pattern recovery, encode,
delta-update — and get back an `OpHandle`. Nothing executes until
`flush()`, which groups *all* pending ops, across independent requests,
into the fewest batched backend calls:

  * reads     — one `BlockStore.get_many` batch per reader cluster
                (one failure-set check + one TrafficStats pass each);
  * recovers  — the pattern-grouped recovery engine: per stripe ONE
                availability scan, fast single-failure groups keyed by
                block id (one `recover_many` launch each), everything
                else keyed by cached DecodePlan identity (one
                `apply_decode_many` launch per live erasure pattern).
                Ten concurrent degraded reads sharing a pattern cost one
                launch, not ten — the cross-request coalescing the
                paper's frequent-concurrent-events regime needs;
  * encodes   — pending (S_i, k, B) payloads are concatenated and
                chunked by `max_batch_stripes`: many small writes ride
                one `encode_many` launch;
  * updates   — delta-parity updates are staged (ALL reads before ANY
                write, preserving the stripe-intact-on-failure
                invariant) and their GF delta terms ride ONE matmul per
                conflict-free wave via a block-structured coefficient
                matrix.

Checkpoint-scale writes bypass the queue through `encode_stream`: a
double-buffered window pipeline (dispatch window w+1's encode lazily,
force + land window w) whose peak memory is O(window) and whose launch
count is ceil(S / max_batch_stripes) — the fused encode+put fast path
`StripeCodec.write_stream` / `CheckpointManager.write_checkpoint` ride.

Execution order within one flush is reads/recovers/encodes first,
mutating updates last; two updates touching the same stripe go in
separate waves, executed in submission order. Errors are per *group*:
a failed batch (NodeFailure, undecodable pattern) marks only its member
ops failed — `OpHandle.result()` re-raises — and the rest of the flush
proceeds, so one doomed request cannot poison a coalesced batch.

The engine is deliberately ignorant of placement and stripe metadata:
it executes byte math and store I/O. Deciding *which* ops realize a
request (read vs recover, which blocks, where rebuilt blocks land) is
the planner's job in `ckpt/stripe.py`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.codec import decode_plan_cached, plans_for
from repro.core.codes import Code
from repro.kernels import ops as kernel_ops
from repro.topo import plan_is_xor_linear

from .backend import Backend


class OpHandle:
    """Future-like result of one submitted op: resolved at flush()."""

    __slots__ = ("_done", "_value", "_exc", "tier", "group")

    def __init__(self) -> None:
        self._done = False
        self._value: Any = None
        self._exc: BaseException | None = None
        self.tier: str | None = None   # recovers: 'fast' | 'pattern'
        self.group: tuple[str, Any] | None = None
        #             recovers: the batch group key this op rode —
        #                      ('fast', block id) or ('pattern', pattern) —
        #                      so planners can attribute per-request stats
        #                      even when a flush coalesced many requests

    @property
    def done(self) -> bool:
        return self._done

    def _set(self, value: Any) -> None:
        self._done, self._value = True, value

    def _fail(self, exc: BaseException) -> None:
        self._done, self._exc = True, exc

    def result(self) -> Any:
        if not self._done:
            raise RuntimeError("op not flushed yet — call engine.flush()")
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclasses.dataclass(eq=False)        # identity hash: ops key batch maps
class _Op:
    kind: str                    # 'read' | 'recover' | 'encode' | 'update'
    handle: OpHandle
    stripe: int = -1
    block: int = -1
    reader_cluster: int | None = None
    strict: bool = True          # recover: raise (True) vs drop to None
    data: np.ndarray | None = None        # encode: (S, k, B)
    new_data: bytes | None = None         # update payload


@dataclasses.dataclass
class FlushStats:
    """How one flush carved the pending ops into batched backend calls."""
    ops: int = 0
    read_batches: int = 0
    encode_batches: int = 0
    fast_groups: int = 0       # single-failure block-id groups
    pattern_groups: int = 0    # distinct multi-erasure patterns decoded
    fast_pairs: int = 0
    multi_pairs: int = 0
    dropped_pairs: int = 0     # non-strict recovers beyond tolerance
    update_waves: int = 0
    gateway_folds: int = 0     # remote-cluster pre-fold launches issued
    aggregated_pairs: int = 0  # pairs served via >= one gateway pre-fold
    launches: int = 0          # kernel launches issued BY this flush
    inner_bytes: int = 0       # store bytes this flush moved, inner tier
    cross_bytes: int = 0       # ... across cluster gateways
    aggregated_bytes: int = 0  # of cross_bytes: gateway pre-folded

    @property
    def plan_groups(self) -> int:
        return self.fast_groups + self.pattern_groups


class CodingEngine:
    """Queue + batched executor over one (code, store, backend) triple.

    `max_batch_stripes` bounds stripes per backend call exactly like the
    pre-refactor StripeCodec bound its launches (peak staging memory ~
    max_batch_stripes * n * block_size bytes)."""

    def __init__(self, code: Code, store: Any, backend: Backend, *,
                 max_batch_stripes: int = 64,
                 gateway_aggregation: bool = False):
        if max_batch_stripes < 1:
            raise ValueError("max_batch_stripes must be >= 1")
        self.code = code
        self.store = store
        self.backend = backend
        self.max_batch_stripes = max_batch_stripes
        # Gateway XOR aggregation (paper §3.3): when a recovery plan is
        # XOR-linear and the reader cluster is known, each remote cluster
        # pre-folds its source blocks at its gateway and ships ONE block.
        # Off by default — it changes launch counts and cross-byte
        # accounting, so callers opt in (the topology benchmark, the
        # degraded-read serving path).
        self.gateway_aggregation = gateway_aggregation
        self._pending: list[_Op] = []

    # -- submission ----------------------------------------------------------
    def _submit(self, op: _Op) -> OpHandle:
        self._pending.append(op)
        return op.handle

    def submit_read(self, stripe: int, block: int, *,
                    reader_cluster: int | None = None) -> OpHandle:
        """Plain block read; result is bytes."""
        return self._submit(_Op("read", OpHandle(), stripe, block,
                                reader_cluster))

    def submit_recover(self, stripe: int, block: int, *,
                       reader_cluster: int | None = None,
                       strict: bool = True) -> OpHandle:
        """Recover one unavailable block; result is bytes, or None when
        strict=False and the stripe's pattern is beyond tolerance."""
        return self._submit(_Op("recover", OpHandle(), stripe, block,
                                reader_cluster, strict))

    def submit_encode(self, data: np.ndarray) -> OpHandle:
        """Encode (S, k, B) uint8 payload; result is (S, n, B) codewords."""
        data = np.ascontiguousarray(data, dtype=np.uint8)
        if data.ndim != 3:
            raise ValueError(f"encode expects (S, k, B), got {data.shape}")
        if data.shape[0] == 0:
            # a zero-stripe op would yield no chunk rows and blow up in
            # the result stack AFTER _pending is cleared, stranding every
            # co-flushed handle — reject at submit time instead
            raise ValueError("encode needs at least one stripe")
        op = _Op("encode", OpHandle())
        op.data = data
        return self._submit(op)

    def submit_update(self, stripe: int, block: int, new_data: bytes, *,
                      reader_cluster: int | None = None) -> OpHandle:
        """Delta-parity partial update of one data block; result is the
        number of parity blocks patched."""
        op = _Op("update", OpHandle(), stripe, block, reader_cluster)
        op.new_data = bytes(new_data)
        return self._submit(op)

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- flush ---------------------------------------------------------------
    def flush(self, *, analyze: bool = False) -> FlushStats:
        if analyze:
            # Debug mode: statically prove the queued schedule hazard-free
            # (waves conflict-free, all-reads-before-any-write, submission
            # order preserved) BEFORE executing anything. Raises
            # HazardViolation with the offending op pair. Lazy import —
            # the analysis subsystem is not on the hot path.
            from repro.analysis.hazards import analyze_flush
            analyze_flush(self, raise_on_violation=True)
        ops_list, self._pending = self._pending, []
        stats = FlushStats(ops=len(ops_list))
        by_kind: dict[str, list[_Op]] = {}
        for op in ops_list:
            by_kind.setdefault(op.kind, []).append(op)
        # Thread-local attribution scopes: the launch/traffic totals on
        # FlushStats stay exact when several shard engines flush
        # concurrently (a global before/after snapshot would fold the
        # other shards' work into this flush's numbers).
        with kernel_ops.launch_scope() as scope, \
                self.store.traffic.scoped() as tdelta:
            self._run_encodes(by_kind.get("encode", []), stats)
            self._run_reads(by_kind.get("read", []), stats)
            self._run_recovers(by_kind.get("recover", []), stats)
            self._run_updates(by_kind.get("update", []), stats)
        stats.launches = scope.total
        stats.inner_bytes = tdelta.inner_bytes
        stats.cross_bytes = tdelta.cross_bytes
        stats.aggregated_bytes = tdelta.aggregated_bytes
        return stats

    # -- reads ---------------------------------------------------------------
    def _run_reads(self, ops_list: list[_Op], stats: FlushStats) -> None:
        by_rc: dict[int | None, list[_Op]] = {}
        for op in ops_list:
            by_rc.setdefault(op.reader_cluster, []).append(op)
        for rc, group in sorted(by_rc.items(),
                                key=lambda kv: (kv[0] is None, kv[0] or 0)):
            pairs = list(dict.fromkeys((op.stripe, op.block)
                                       for op in group))
            try:
                got = self.store.get_many(pairs, reader_cluster=rc)
            except Exception:
                # A bad pair fails the whole batched check before any
                # traffic is recorded; retry per op so only the ops that
                # actually touch the failed/missing block error out.
                for op in group:
                    try:
                        op.handle._set(self.store.get(
                            op.stripe, op.block, reader_cluster=rc))
                    except Exception as exc:
                        op.handle._fail(exc)
                continue
            stats.read_batches += 1
            for op in group:
                op.handle._set(got[(op.stripe, op.block)])

    # -- recovers (the pattern-grouped engine) -------------------------------
    def _gather_sources(self, sids: list[int], sources: tuple[int, ...],
                        rc: int | None) -> dict[int, np.ndarray]:
        """{source block id: (S, B)} for a plan group, read via ONE
        get_many batch."""
        got = self.store.get_many(
            [(sid, s) for sid in sids for s in sources], reader_cluster=rc)
        return {s: np.stack([np.frombuffer(got[(sid, s)], np.uint8)
                             for sid in sids]) for s in sources}

    def _should_aggregate(self, rc: int | None, plan) -> bool:
        return (self.gateway_aggregation and rc is not None
                and plan_is_xor_linear(plan))

    def _source_clusters(self, sid: int, sources) -> tuple[int, ...]:
        """Where each source block of `sid` physically lives right now —
        rebuilt blocks may sit on fallback nodes, so ask the store, not
        the placement."""
        topo = self.store.topo
        return tuple(topo.cluster_of(self.store.node_of(sid, s))
                     for s in sources)

    def _recover_xor_batch(self, sids: list[int], sources: tuple[int, ...],
                           rc: int | None, stats: FlushStats
                           ) -> np.ndarray:
        """Gateway-aggregated execution of one XOR-linear plan over a
        stripe batch: remote clusters holding >= 2 sources read them
        locally (inner-tier bytes at THEIR gateway), fold them with one
        `xor_fold_many` launch, and ship one pre-folded block per
        stripe (cross-tier `aggregated_bytes`); the reader folds the
        partials with its own local + singleton-remote sources. XOR
        associativity makes the result byte-identical to the direct
        fold of all sources, on either backend."""
        groups: dict[tuple[int, ...], list[int]] = {}
        for i, sid in enumerate(sids):
            sig = self._source_clusters(sid, sources)
            groups.setdefault(sig, []).append(i)
        results: list[np.ndarray | None] = [None] * len(sids)
        for sig, poss in sorted(groups.items()):
            gsids = [sids[i] for i in poss]
            by_c: dict[int, list[int]] = {}
            for s, c in zip(sources, sig):
                by_c.setdefault(c, []).append(s)
            direct = [s for c, ss in sorted(by_c.items())
                      if c == rc or len(ss) == 1 for s in ss]
            folds = {c: ss for c, ss in by_c.items()
                     if c != rc and len(ss) > 1}
            parts: list[np.ndarray] = []
            if direct:
                got = self._gather_sources(gsids, tuple(direct), rc)
                parts += [got[s] for s in direct]
            for c, ss in sorted(folds.items()):
                got = self._gather_sources(gsids, tuple(ss), c)
                partial = self.backend.xor_fold_many(
                    np.stack([got[s] for s in ss], axis=1))
                stats.gateway_folds += 1
                self.store.traffic.add_shipped(int(partial.nbytes))
                parts.append(partial)
            rec = self.backend.xor_fold_many(np.stack(parts, axis=1))
            if folds:
                stats.aggregated_pairs += len(gsids)
            for i, row in zip(poss, rec):
                results[i] = row
        return np.stack(results)

    def _run_recovers(self, ops_list: list[_Op], stats: FlushStats) -> None:
        by_rc: dict[int | None, list[_Op]] = {}
        for op in ops_list:
            by_rc.setdefault(op.reader_cluster, []).append(op)
        for rc, group in sorted(by_rc.items(),
                                key=lambda kv: (kv[0] is None, kv[0] or 0)):
            self._recover_cluster_group(rc, group, stats)

    def _recover_cluster_group(self, rc: int | None, group: list[_Op],
                               stats: FlushStats) -> None:
        pair_ops: dict[tuple[int, int], list[_Op]] = {}
        by_stripe: dict[int, list[int]] = {}
        for op in group:
            key = (op.stripe, op.block)
            if key not in pair_ops:
                by_stripe.setdefault(op.stripe, []).append(op.block)
            pair_ops.setdefault(key, []).append(op)
        plans = plans_for(self.code)
        n = self.code.n
        fast: dict[int, list[int]] = {}      # block id -> [stripe ids]
        # pattern -> [(stripe id, requested blocks under that pattern)]
        slow: dict[tuple[int, ...], list[tuple[int, list[int]]]] = {}
        for sid in sorted(by_stripe):
            eset = {b for b in range(n)
                    if not self.store.available(sid, b)}
            slow_blocks = []
            for b in by_stripe[sid]:
                if eset.intersection(plans[b].sources):
                    slow_blocks.append(b)
                else:
                    fast.setdefault(b, []).append(sid)
            if slow_blocks:
                pattern = tuple(sorted(eset.union(slow_blocks)))
                slow.setdefault(pattern, []).append((sid, slow_blocks))

        def resolve(sid: int, b: int, data: bytes, tier: str,
                    group) -> None:
            for op in pair_ops[(sid, b)]:
                op.handle.tier = tier
                op.handle.group = group
                op.handle._set(data)

        def fail_pairs(keys: list[tuple[int, int]],
                       exc: BaseException) -> None:
            for key in keys:
                for op in pair_ops[key]:
                    op.handle._fail(exc)

        for b, sids in sorted(fast.items()):
            plan = plans[b]
            stats.fast_groups += 1
            aggregate = self._should_aggregate(rc, plan)
            for i0 in range(0, len(sids), self.max_batch_stripes):
                batch = sids[i0:i0 + self.max_batch_stripes]
                try:
                    if aggregate:
                        rec = self._recover_xor_batch(batch, plan.sources,
                                                      rc, stats)
                    else:
                        stacked = self._gather_sources(batch, plan.sources,
                                                       rc)
                        rec = self.backend.recover_many(plan, stacked)
                except Exception as exc:
                    fail_pairs([(sid, b) for sid in batch], exc)
                    continue
                for i, sid in enumerate(batch):
                    resolve(sid, b, rec[i].tobytes(), "fast", ("fast", b))
                    stats.fast_pairs += 1

        for pattern, entries in sorted(slow.items()):
            keys = [(sid, b) for sid, blocks in entries for b in blocks]
            try:
                dplan = decode_plan_cached(self.code, pattern)
            except ValueError as exc:   # beyond the code's tolerance now
                for key in keys:
                    for op in pair_ops[key]:
                        if op.strict:
                            op.handle._fail(exc)
                        else:
                            op.handle._set(None)
                            stats.dropped_pairs += 1
                continue
            stats.pattern_groups += 1
            # Every member stripe's erased set is a subset of `pattern`,
            # so the plan's sources are alive for the whole group. (No
            # gateway pre-fold here: a pattern group always decodes >= 2
            # erased blocks, which fails the single-target XOR-linearity
            # check a plain-XOR gateway needs.)
            for i0 in range(0, len(entries), self.max_batch_stripes):
                chunk = entries[i0:i0 + self.max_batch_stripes]
                sids = [sid for sid, _ in chunk]
                try:
                    stacked = self._gather_sources(sids, dplan.sources, rc)
                    rec = self.backend.apply_decode_many(dplan, stacked)
                except Exception as exc:
                    fail_pairs([(sid, b) for sid, blocks in chunk
                                for b in blocks], exc)
                    continue
                for i, (sid, blocks) in enumerate(chunk):
                    for b in blocks:
                        resolve(sid, b, rec[b][i].tobytes(), "pattern",
                                ("pattern", pattern))
                        stats.multi_pairs += 1

    # -- encodes -------------------------------------------------------------
    def _run_encodes(self, ops_list: list[_Op], stats: FlushStats) -> None:
        by_shape: dict[tuple[int, int], list[_Op]] = {}
        for op in ops_list:
            by_shape.setdefault(op.data.shape[1:], []).append(op)
        for _shape, group in sorted(by_shape.items()):
            # Flatten every pending payload's stripes into one row list,
            # then chunk: many small writes coalesce into one launch.
            rows = [(op, i) for op in group for i in range(len(op.data))]
            outs = {id(op): [] for op in group}
            for i0 in range(0, len(rows), self.max_batch_stripes):
                chunk = rows[i0:i0 + self.max_batch_stripes]
                op0, first = chunk[0]
                # Rows of one op are consecutive by construction, so a
                # single-op chunk is a contiguous slice of its payload:
                # hand the backend a VIEW instead of np.stack's copy —
                # on the checkpoint write path that copy was O(window)
                # per chunk for nothing.
                whole = all(op is op0 for op, _ in chunk)
                data = (op0.data[first:first + len(chunk)] if whole
                        else np.stack([op.data[i] for op, i in chunk]))
                try:
                    cw = self.backend.encode_many(self.code, data)
                except Exception as exc:
                    for op in dict.fromkeys(op for op, _ in chunk):
                        if not op.handle.done:
                            op.handle._fail(exc)
                    continue
                stats.encode_batches += 1
                if whole and len(chunk) == len(op0.data):
                    op0.handle._set(cw)     # one chunk == the whole op
                    continue
                for j, (op, _i) in enumerate(chunk):
                    outs[id(op)].append(cw[j])
            for op in group:
                if not op.handle.done:
                    op.handle._set(np.stack(outs[id(op)]))

    # -- streaming encode (checkpoint write fast path) -----------------------
    def encode_stream(self, windows, sink) -> int:
        """Double-buffered streaming encode: the checkpoint-scale write
        path, fused with store landing.

        `windows` yields (S_w, k, B) uint8 arrays (views are fine —
        nothing is copied here), each with S_w <= max_batch_stripes;
        `sink(index, codewords)` receives every window's forced (S_w,
        n, B) result, in order. The pipeline overlap: window w+1's
        encode is DISPATCHED (`Backend.encode_many_lazy` — un-forced
        jax array on the kernel backend) before window w's result is
        forced and handed to the sink, so device compute runs while the
        host lands blocks. At most two windows of codewords are live at
        once — peak memory is O(window), not O(buffer) — and each
        window is exactly one backend call, so a buffer of S stripes
        costs ceil(S / window) launches, same as the queued path.

        This bypasses the op queue (no coalescing with pending ops —
        callers sequence it like any other store mutation); launches
        and traffic still ride the thread-local attribution scopes.
        Returns the number of windows encoded."""
        served = 0
        prev: tuple[int, Any] | None = None
        with kernel_ops.launch_scope(), self.store.traffic.scoped():
            for view in windows:
                data = np.ascontiguousarray(view, dtype=np.uint8)
                if data.ndim != 3 or data.shape[1] != self.code.k:
                    raise ValueError(
                        f"encode_stream expects (S, k={self.code.k}, B) "
                        f"windows, got {data.shape}")
                if not 1 <= data.shape[0] <= self.max_batch_stripes:
                    raise ValueError(
                        f"window of {data.shape[0]} stripes outside "
                        f"[1, max_batch_stripes={self.max_batch_stripes}]")
                fut = self.backend.encode_many_lazy(self.code, data)
                if prev is not None:
                    sink(prev[0], np.asarray(prev[1]))
                prev = (served, fut)
                served += 1
            if prev is not None:
                sink(prev[0], np.asarray(prev[1]))
        return served

    # -- delta updates -------------------------------------------------------
    def _run_updates(self, ops_list: list[_Op], stats: FlushStats) -> None:
        # Waves: submission order, one op per stripe per wave (updates of
        # one stripe share parity blocks, so they must see each other's
        # writes), uniform payload length + reader cluster per wave so
        # the delta terms stack into one matmul.
        remaining = list(ops_list)
        while remaining:
            wave: list[_Op] = []
            stripes: set[int] = set()    # stripes in the wave OR deferred —
            key = None                   # keeps per-stripe submission order
            deferred: list[_Op] = []
            for op in remaining:
                okey = (len(op.new_data), op.reader_cluster)
                if op.stripe in stripes or (key is not None and okey != key):
                    deferred.append(op)
                    stripes.add(op.stripe)
                    continue
                key = okey
                stripes.add(op.stripe)
                wave.append(op)
            remaining = deferred
            self._run_update_wave(wave, stats)

    def _run_update_wave(self, wave: list[_Op], stats: FlushStats) -> None:
        code, k = self.code, self.code.k
        rc = wave[0].reader_cluster
        touched_of = {}
        read_pairs: list[tuple[int, int]] = []
        for op in wave:
            coeffs = code.A[:, op.block]
            touched_of[id(op)] = [int(pi) for pi in np.flatnonzero(coeffs)]
            read_pairs.append((op.stripe, op.block))
            read_pairs += [(op.stripe, k + pi) for pi in touched_of[id(op)]]
        # Stage phase: EVERY read happens before ANY write, one batched
        # get_many — a NodeFailure anywhere aborts the whole wave with
        # every stripe untouched.
        try:
            got = self.store.get_many(read_pairs, reader_cluster=rc)
        except Exception as exc:
            for op in wave:
                op.handle._fail(exc)
            return
        try:
            deltas, rows = [], []      # rows: (term row -> (op idx, pi))
            for u, op in enumerate(wave):
                old = np.frombuffer(got[(op.stripe, op.block)], np.uint8)
                new = np.frombuffer(op.new_data, np.uint8)
                if new.shape != old.shape:
                    raise ValueError(
                        f"update payload is {new.size} bytes but stripe "
                        f"{op.stripe} block {op.block} holds {old.size}")
                deltas.append(old ^ new)
                rows += [(u, pi) for pi in touched_of[id(op)]]
            if rows:
                # Block-structured coefficient matrix: one column per
                # update, one row per touched parity term — ALL delta
                # terms of the wave ride a single GF matmul.
                M = np.zeros((len(rows), len(wave)), dtype=np.uint8)
                for r, (u, pi) in enumerate(rows):
                    M[r, u] = code.A[pi, wave[u].block]
                terms = self.backend.delta_terms(M, np.stack(deltas))
        except Exception as exc:       # nothing written yet: wave aborts
            for op in wave:
                op.handle._fail(exc)
            return
        stats.update_waves += 1
        # Apply phase: every source value is staged, so no read can fail
        # between the first and last put. A put() error is a genuine
        # partial write — surface it on every unresolved handle rather
        # than stranding them pending forever.
        try:
            r = 0
            for u, op in enumerate(wave):
                sid = op.stripe
                self.store.put(sid, op.block,
                               self.store.node_of(sid, op.block),
                               op.new_data)
                for pi in touched_of[id(op)]:
                    pblock = k + pi
                    pold = np.frombuffer(got[(sid, pblock)], np.uint8)
                    self.store.put(sid, pblock,
                                   self.store.node_of(sid, pblock),
                                   (pold ^ terms[r]).tobytes())
                    r += 1
                op.handle._set(len(touched_of[id(op)]))
        except Exception as exc:
            for op in wave:
                if not op.handle.done:
                    op.handle._fail(exc)
