"""RequestFrontend: priority-classed request queue over the CodingEngine.

The paper's availability argument (§2.2/§5) is about serving under
*frequent concurrent events*: many clients hitting degraded stripes at
once while background rebuild and scrub traffic competes for the same
coding path. The front-end is the request-level layer the synchronous
`StripeCodec` API could not provide:

  * requests (client read, degraded read, rebuild, scrub) queue in three
    priority classes — CLIENT_READ > DEGRADED_READ > BACKGROUND — and
    execute at flush boundaries, class by class, so a rebuild storm can
    never starve client reads;
  * within one class flush, every request's ops enter the engine before
    one `engine.flush()`: N concurrent degraded reads sharing a live
    erasure pattern coalesce into O(#patterns) kernel launches;
  * BACKGROUND work is metered by `background_ops_per_flush` — a storm
    is chunked across flush cycles, with leftover requests re-queued
    ahead of newly arriving background work;
  * per-class accounting (`ClassStats`): requests, blocks, kernel
    launches, inner/cross traffic bytes, and queue-to-completion latency
    — the numbers `benchmarks/fig_mixed_workload.py` reports and CI
    gates.

Requests are planned lazily AT flush time (availability is read then,
not at submit time) via the two-phase planner API on `StripeCodec`:
`plan_*` submits engine ops and returns a finish closure. Mutating
requests (rebuild placement) apply their writes in the finish phase,
after the class's batched reads have executed.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable

import numpy as np

from repro.kernels import ops as kernel_ops
# Canonical home is repro.priority (shared with the repair scheduler's
# risk tiers); re-exported here for the historical import path.
from repro.priority import ClassStats, Priority

__all__ = ["Priority", "ClassStats", "ScrubReport", "RequestHandle",
           "RequestFrontend"]


@dataclasses.dataclass(frozen=True)
class ScrubReport:
    """Background integrity pass: re-encode data blocks, compare parities."""
    stripes: int                 # stripes requested
    checked: int                 # stripes fully available and verified
    skipped: int                 # degraded stripes left to repair, not scrub
    mismatched: tuple[tuple[int, int], ...]   # (stripe, block) parity drift


class RequestHandle:
    """Future-like request result; resolved when its class flushes."""

    __slots__ = ("priority", "kind", "size", "_done", "_value", "_exc",
                 "_submitted", "latency_s")

    def __init__(self, priority: Priority, kind: str, size: int):
        self.priority = priority
        self.kind = kind
        self.size = size                 # block count — the metering unit
        self._done = False
        self._value = None
        self._exc: BaseException | None = None
        self._submitted = time.perf_counter()
        self.latency_s = 0.0

    @property
    def done(self) -> bool:
        return self._done

    def _resolve(self, value, exc: BaseException | None) -> None:
        self._done, self._value, self._exc = True, value, exc
        self.latency_s = time.perf_counter() - self._submitted

    def result(self):
        if not self._done:
            raise RuntimeError("request not flushed yet")
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclasses.dataclass(eq=False)
class _Request:
    handle: RequestHandle
    plan: Callable[[], Callable[[], object]]   # () -> finish closure


class RequestFrontend:
    """Coalescing, priority-classed request layer over one StripeCodec."""

    def __init__(self, codec, *,
                 background_ops_per_flush: int | None = None):
        if (background_ops_per_flush is not None
                and background_ops_per_flush < 1):
            raise ValueError("background_ops_per_flush must be >= 1")
        self.codec = codec
        self.background_ops_per_flush = background_ops_per_flush
        self._queues: dict[Priority, list[_Request]] = {
            p: [] for p in Priority}
        self.stats: dict[Priority, ClassStats] = {
            p: ClassStats() for p in Priority}

    # -- submission ----------------------------------------------------------
    def _enqueue(self, priority: Priority, kind: str, size: int,
                 plan: Callable[[], Callable[[], object]]) -> RequestHandle:
        handle = RequestHandle(priority, kind, size)
        self._queues[priority].append(_Request(handle, plan))
        return handle

    def submit_client_read(self, meta, *,
                           reader_cluster: int | None = None
                           ) -> RequestHandle:
        """Full-stripe read (CheckpointManager-style restore traffic)."""
        return self._enqueue(
            Priority.CLIENT_READ, "client_read", self.codec.code.k,
            lambda: self.codec.plan_normal_read(
                meta, reader_cluster=reader_cluster))

    def submit_degraded_read(self, meta, block: int, *,
                             reader_cluster: int | None = None
                             ) -> RequestHandle:
        """One unavailable block served from survivors."""
        return self._enqueue(
            Priority.DEGRADED_READ, "degraded_read", 1,
            lambda: self.codec.plan_degraded_read(
                meta, block, reader_cluster=reader_cluster))

    def submit_rebuild(self, pairs: list[tuple[int, int]], *,
                       reader_cluster: int | None = None,
                       exclude_node: int = -1,
                       priority: Priority = Priority.BACKGROUND
                       ) -> RequestHandle:
        """Re-protect; result is (placed, RecoveryStats). Routine rebuild
        rides BACKGROUND; the repair scheduler escalates an almost-exposed
        stripe's rebuild to its RAFI risk tier (URGENT/EXPEDITED alias
        onto the serving classes — see repro.priority)."""
        return self._enqueue(
            Priority(priority), "rebuild", len(dict.fromkeys(pairs)),
            lambda: self.codec.plan_rebuild(
                pairs, reader_cluster=reader_cluster,
                exclude_node=exclude_node))

    def submit_scrub(self, metas, *,
                     reader_cluster: int | None = None) -> RequestHandle:
        """Background integrity scan; result is a ScrubReport.

        One request reads every block of every listed stripe in its
        class flush, so its resident bytes scale with len(metas) — for
        checkpoint-scale scrubs submit slices of metas (and/or set
        background_ops_per_flush, which meters whole requests)."""
        return self._enqueue(
            Priority.BACKGROUND, "scrub",
            len(metas) * self.codec.code.n,
            lambda: self._plan_scrub(metas, reader_cluster))

    # -- scrub planner -------------------------------------------------------
    def _plan_scrub(self, metas, reader_cluster: int | None):
        codec = self.codec
        n, k = codec.code.n, codec.code.k
        handles: dict[int, list] = {}
        skipped = 0
        for meta in metas:
            sid = meta.stripe_id
            if all(codec.store.available(sid, b) for b in range(n)):
                handles[sid] = [codec.engine.submit_read(
                    sid, b, reader_cluster=reader_cluster)
                    for b in range(n)]
            else:
                skipped += 1        # degraded: repair's job, not scrub's

        def finish() -> ScrubReport:
            mismatched: list[tuple[int, int]] = []
            sids = sorted(handles)
            # Re-encode in max_batch_stripes chunks so the numpy staging
            # + encode launch obey the engine's per-batch ceiling. The
            # flush's resolved read payloads still scale with the scrub's
            # total bytes — bound THAT by submitting large scrubs in
            # slices, or set background_ops_per_flush so the metering
            # spreads them across cycles.
            step = codec.max_batch_stripes
            for i0 in range(0, len(sids), step):
                chunk = sids[i0:i0 + step]
                stored = {sid: [np.frombuffer(h.result(), np.uint8)
                                for h in handles[sid]] for sid in chunk}
                data = np.stack([np.stack(stored[sid][:k])
                                 for sid in chunk])
                expect = codec.backend.encode_many(codec.code, data)
                for i, sid in enumerate(chunk):
                    for b in range(k, n):
                        if not np.array_equal(expect[i, b],
                                              stored[sid][b]):
                            mismatched.append((sid, b))
            return ScrubReport(stripes=len(metas), checked=len(handles),
                               skipped=skipped,
                               mismatched=tuple(mismatched))
        return finish

    # -- flush ---------------------------------------------------------------
    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _take(self, priority: Priority) -> list[_Request]:
        queue = self._queues[priority]
        if priority is not Priority.BACKGROUND \
                or self.background_ops_per_flush is None:
            self._queues[priority] = []
            return queue
        take, size = [], 0
        while queue and (not take
                         or size + queue[0].handle.size
                         <= self.background_ops_per_flush):
            req = queue.pop(0)
            take.append(req)
            size += req.handle.size
        return take

    def flush(self) -> int:
        """One cycle: serve every class in priority order (client reads
        first, background last and metered). Returns requests served."""
        served = 0
        for priority in Priority:
            batch = self._take(priority)
            if not batch:
                continue
            served += len(batch)
            cls = self.stats[priority]
            cls.flushes += 1
            snap = kernel_ops.kernel_launch_snapshot()
            traffic = self.codec.store.traffic
            inner0, cross0 = traffic.inner_bytes, traffic.cross_bytes
            agg0 = traffic.aggregated_bytes
            finishes: list[tuple[_Request, Callable | None]] = []
            for req in batch:
                try:
                    finishes.append((req, req.plan()))
                except Exception as exc:
                    req.handle._resolve(None, exc)
                    finishes.append((req, None))
            self.codec.engine.flush()
            for req, finish in finishes:
                if finish is None:
                    pass
                else:
                    try:
                        req.handle._resolve(finish(), None)
                    except Exception as exc:
                        req.handle._resolve(None, exc)
                cls.requests += 1
                cls.blocks += req.handle.size
                if req.handle._exc is not None:
                    cls.failed_requests += 1
                cls.total_latency_s += req.handle.latency_s
                cls.max_latency_s = max(cls.max_latency_s,
                                        req.handle.latency_s)
            cls.launches += kernel_ops.launches_since(snap)
            cls.inner_bytes += traffic.inner_bytes - inner0
            cls.cross_bytes += traffic.cross_bytes - cross0
            cls.aggregated_bytes += traffic.aggregated_bytes - agg0
        return served

    def drain(self) -> int:
        """Flush cycles until every queue is empty (background metering
        spreads a storm over several cycles). Returns requests served."""
        served = 0
        while self.pending:
            served += self.flush()
        return served

    # -- repair-scheduler convenience ---------------------------------------
    def rebuild(self, pairs: list[tuple[int, int]], *,
                reader_cluster: int | None = None,
                exclude_node: int = -1,
                priority: Priority = Priority.BACKGROUND):
        """Submit one rebuild request and drain it immediately, returning
        the same `RepairReport` the codec's synchronous path produces —
        the hook `sim/repair.py`'s data-path mode drives. Launch/traffic
        deltas are exact when no other request is pending (the repair
        scheduler runs one job at a time); with concurrent requests they
        cover the whole drain window."""
        from repro.ckpt.stripe import RepairReport
        requested = len(dict.fromkeys(pairs))
        snap = kernel_ops.kernel_launch_snapshot()
        traffic = self.codec.store.traffic
        inner0, cross0 = traffic.inner_bytes, traffic.cross_bytes
        agg0 = traffic.aggregated_bytes
        handle = self.submit_rebuild(pairs, reader_cluster=reader_cluster,
                                     exclude_node=exclude_node,
                                     priority=priority)
        self.drain()
        placed, stats = handle.result()
        return RepairReport(
            requested=requested, placed=placed,
            launches=kernel_ops.launches_since(snap),
            inner_bytes=traffic.inner_bytes - inner0,
            cross_bytes=traffic.cross_bytes - cross0,
            plan_groups=stats.plan_groups, patterns=stats.pattern_groups,
            multi_pairs=stats.multi_pairs,
            aggregated_bytes=traffic.aggregated_bytes - agg0)
