"""Priority-classed, shard-parallel serving layer over the CodingEngine.

The paper's availability argument (§2.2/§5) is about serving under
*frequent concurrent events*: many clients hitting degraded stripes at
once while background rebuild and scrub traffic competes for the same
coding path. Two layers provide that:

`RequestFrontend` — one shard's worth of the serving path:

  * requests (client read, degraded read, rebuild, scrub) queue in three
    priority classes — CLIENT_READ > DEGRADED_READ > BACKGROUND — and
    execute at flush boundaries, class by class, so a rebuild storm can
    never starve client reads;
  * within one class flush, every request's ops enter the engine before
    one `engine.flush()`: N concurrent degraded reads sharing a live
    erasure pattern coalesce into O(#patterns) kernel launches;
  * BACKGROUND work is metered by `background_ops_per_flush` — a storm
    is chunked across flush cycles, with leftover requests re-queued
    ahead of newly arriving background work;
  * admission control (`repro.priority.AdmissionController`): per-tenant
    token buckets plus load-shedding watermarks — BACKGROUND sheds
    first, DEGRADED_READ second, CLIENT_READ never watermark-sheds. A
    shed request's handle resolves with `RequestShed` and counts in
    `ClassStats.shed_requests` (submitted == served + shed, exactly);
  * the degraded-read hot-block cache (`repro.io.HotBlockCache`) sits in
    FRONT of the queue: a hit is served at submit time with zero engine
    ops, so a same-block degraded-read storm costs O(1) decodes instead
    of O(requests). Store mutation listeners invalidate eagerly, making
    cached/uncached byte-identity an invariant, not a convention;
  * time is injectable: `clock` (any `() -> float`) stamps submit and
    resolve, so latency accounting is deterministic under the
    benchmark's `VirtualClock` and testable without sleeps. With a
    `service_model` hook, each class flush advances the (virtual) clock
    by the modeled service time of the work it just executed — the
    saturation benchmark's per-shard timeline;
  * per-class accounting (`ClassStats`) via *thread-local* attribution
    scopes (`kernel_ops.launch_scope`, `TrafficStats.scoped`), so the
    numbers stay exact when many shards flush concurrently.

`ShardedFrontend` — the pipelined multi-shard composition: stripe
ownership is sharded by `stripe % num_shards`, each shard owning a
`StripeCodec.clone()` (fresh engine queue, shared store/metadata) so
submit -> plan -> flush overlap across shards on a worker pool while
kernels still batch per shard. Admission and the hot-block cache are
shared across shards; `stats` is the cross-shard `ClassStats` merge.
Multi-stripe requests (rebuild, scrub) split by shard and return a
merged handle; admission charges them once, at the sharded layer.

Requests are planned lazily AT flush time (availability is read then,
not at submit time) via the two-phase planner API on `StripeCodec`:
`plan_*` submits engine ops and returns a finish closure. Mutating
requests (rebuild placement) apply their writes in the finish phase,
after the class's batched reads have executed.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections.abc import Callable
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.kernels import ops as kernel_ops
# Canonical home is repro.priority (shared with the repair scheduler's
# risk tiers); re-exported here for the historical import path.
from repro.priority import (AdmissionController, ClassStats, Priority,
                            RequestShed, merge_class_stats)

from .cache import HotBlockCache

__all__ = ["Priority", "ClassStats", "ScrubReport", "RequestHandle",
           "MergedHandle", "ServiceSample", "RequestFrontend",
           "ShardedFrontend", "RequestShed"]


@dataclasses.dataclass(frozen=True)
class ScrubReport:
    """Background integrity pass: re-encode data blocks, compare parities."""
    stripes: int                 # stripes requested
    checked: int                 # stripes fully available and verified
    skipped: int                 # degraded stripes left to repair, not scrub
    mismatched: tuple[tuple[int, int], ...]   # (stripe, block) parity drift


@dataclasses.dataclass(frozen=True)
class ServiceSample:
    """What one class flush executed — the argument to the front-end's
    `service_model` hook, which maps it to modeled service seconds (the
    virtual-time cost the saturation benchmark charges per flush)."""
    priority: Priority
    requests: int
    blocks: int
    launches: int
    inner_bytes: int
    cross_bytes: int
    aggregated_bytes: int


class RequestHandle:
    """Future-like request result; resolved when its class flushes (or
    at submit time, for cache hits and admission sheds)."""

    __slots__ = ("priority", "kind", "size", "_done", "_value", "_exc",
                 "_clock", "_submitted", "latency_s")

    def __init__(self, priority: Priority, kind: str, size: int,
                 clock: Callable[[], float] = time.perf_counter):
        self.priority = priority
        self.kind = kind
        self.size = size                 # block count — the metering unit
        self._done = False
        self._value = None
        self._exc: BaseException | None = None
        self._clock = clock
        self._submitted = clock()
        self.latency_s = 0.0

    @property
    def done(self) -> bool:
        return self._done

    @property
    def shed(self) -> bool:
        return self._done and isinstance(self._exc, RequestShed)

    def _resolve(self, value, exc: BaseException | None) -> None:
        self._done, self._value, self._exc = True, value, exc
        self.latency_s = self._clock() - self._submitted

    def result(self):
        if not self._done:
            raise RuntimeError("request not flushed yet")
        if self._exc is not None:
            raise self._exc
        return self._value


class MergedHandle:
    """Handle over per-shard child handles of one multi-stripe request
    (rebuild/scrub split by stripe ownership). Resolves when every child
    has; `latency_s` is the slowest child's."""

    __slots__ = ("priority", "kind", "size", "_children", "_combine")

    def __init__(self, priority: Priority, kind: str, size: int,
                 children: list[RequestHandle],
                 combine: Callable[[list], object]):
        self.priority = priority
        self.kind = kind
        self.size = size
        self._children = children
        self._combine = combine

    @property
    def done(self) -> bool:
        return all(c.done for c in self._children)

    @property
    def shed(self) -> bool:
        return any(c.shed for c in self._children)

    @property
    def latency_s(self) -> float:
        return max((c.latency_s for c in self._children), default=0.0)

    def result(self):
        return self._combine([c.result() for c in self._children])


@dataclasses.dataclass(eq=False)
class _Request:
    handle: RequestHandle
    plan: Callable[[], Callable[[], object]]   # () -> finish closure


class RequestFrontend:
    """Coalescing, priority-classed request layer over one StripeCodec.

    One instance is one *shard*: `flush()`/`drain()` are driven by a
    single thread at a time (the sharded layer's worker pool guarantees
    this), while submissions and stat reads are safe from any thread."""

    def __init__(self, codec, *,
                 background_ops_per_flush: int | None = None,
                 clock: Callable[[], float] | None = None,
                 cache: HotBlockCache | None = None,
                 admission: AdmissionController | None = None,
                 admission_pending: Callable[[], int] | None = None,
                 service_model: Callable[[ServiceSample], float] | None = None,
                 deadline_s: dict[Priority, float] | None = None,
                 analyze_flushes: bool = False):
        if (background_ops_per_flush is not None
                and background_ops_per_flush < 1):
            raise ValueError("background_ops_per_flush must be >= 1")
        self.codec = codec
        self.background_ops_per_flush = background_ops_per_flush
        self.clock = clock or time.perf_counter
        self.cache = cache
        if cache is not None:
            cache.attach(codec.store)
        self.admission = admission
        # Watermark sheds are judged against this pending count — the
        # sharded layer points every shard at the GLOBAL backlog so one
        # hot shard cannot hide overload from the others.
        self._admission_pending = admission_pending or (lambda: self.pending)
        self.service_model = service_model
        if deadline_s is None and admission is not None:
            deadline_s = dict(admission.config.deadline_s)
        self.deadline_s = deadline_s or {}
        self.analyze_flushes = analyze_flushes
        self.hazard_checked_flushes = 0
        self._lock = threading.Lock()
        self._queues: dict[Priority, list[_Request]] = {
            p: [] for p in Priority}
        self.stats: dict[Priority, ClassStats] = {
            p: ClassStats() for p in Priority}

    # -- submission ----------------------------------------------------------
    def _shed(self, priority: Priority, kind: str, size: int,
              reason: str, tenant: str | None) -> RequestHandle:
        handle = RequestHandle(priority, kind, size, clock=self.clock)
        handle._resolve(None, RequestShed(reason, priority, tenant))
        with self._lock:
            self.stats[priority].shed_requests += 1
        return handle

    def _enqueue(self, priority: Priority, kind: str, size: int,
                 plan: Callable[[], Callable[[], object]], *,
                 tenant: str | None = None,
                 admitted: bool = False) -> RequestHandle:
        priority = Priority(priority)
        if self.admission is not None and not admitted:
            reason = self.admission.admit(
                priority, size, pending=self._admission_pending(),
                tenant=tenant)
            if reason is not None:
                return self._shed(priority, kind, size, reason, tenant)
        handle = RequestHandle(priority, kind, size, clock=self.clock)
        with self._lock:
            self._queues[priority].append(_Request(handle, plan))
        return handle

    def submit_client_read(self, meta, *,
                           reader_cluster: int | None = None,
                           tenant: str | None = None,
                           _admitted: bool = False) -> RequestHandle:
        """Full-stripe read (CheckpointManager-style restore traffic)."""
        return self._enqueue(
            Priority.CLIENT_READ, "client_read", self.codec.code.k,
            lambda: self.codec.plan_normal_read(
                meta, reader_cluster=reader_cluster),
            tenant=tenant, admitted=_admitted)

    def submit_degraded_read(self, meta, block: int, *,
                             reader_cluster: int | None = None,
                             tenant: str | None = None,
                             _admitted: bool = False) -> RequestHandle:
        """One unavailable block served from survivors — or from the
        hot-block cache, at submit time, with zero engine ops. A hit
        bypasses admission entirely: it never touches the coding path
        admission protects."""
        sid = meta.stripe_id
        if self.cache is not None:
            data = self.cache.get(sid, block)
            if data is not None:
                handle = RequestHandle(Priority.DEGRADED_READ,
                                       "degraded_read", 1, clock=self.clock)
                handle._resolve(data, None)
                with self._lock:
                    cls = self.stats[Priority.DEGRADED_READ]
                    cls.requests += 1
                    cls.blocks += 1
                    cls.cache_hits += 1
                    cls.total_latency_s += handle.latency_s
                    cls.max_latency_s = max(cls.max_latency_s,
                                            handle.latency_s)
                return handle
        return self._enqueue(
            Priority.DEGRADED_READ, "degraded_read", 1,
            lambda: self._plan_degraded(meta, block, reader_cluster),
            tenant=tenant, admitted=_admitted)

    def _plan_degraded(self, meta, block: int,
                       reader_cluster: int | None) -> Callable[[], bytes]:
        finish = self.codec.plan_degraded_read(
            meta, block, reader_cluster=reader_cluster)
        if self.cache is None:
            return finish
        sid = meta.stripe_id

        def finish_and_fill() -> bytes:
            data = finish()
            self.cache.put(sid, block, data)
            return data
        return finish_and_fill

    def submit_rebuild(self, pairs: list[tuple[int, int]], *,
                       reader_cluster: int | None = None,
                       exclude_node: int = -1,
                       priority: Priority = Priority.BACKGROUND,
                       tenant: str | None = None,
                       _admitted: bool = False) -> RequestHandle:
        """Re-protect; result is (placed, RecoveryStats). Routine rebuild
        rides BACKGROUND; the repair scheduler escalates an almost-exposed
        stripe's rebuild to its RAFI risk tier (URGENT/EXPEDITED alias
        onto the serving classes — see repro.priority)."""
        return self._enqueue(
            Priority(priority), "rebuild", len(dict.fromkeys(pairs)),
            lambda: self.codec.plan_rebuild(
                pairs, reader_cluster=reader_cluster,
                exclude_node=exclude_node),
            tenant=tenant, admitted=_admitted)

    def submit_scrub(self, metas, *,
                     reader_cluster: int | None = None,
                     tenant: str | None = None,
                     _admitted: bool = False) -> RequestHandle:
        """Background integrity scan; result is a ScrubReport.

        One request reads every block of every listed stripe in its
        class flush, so its resident bytes scale with len(metas) — for
        checkpoint-scale scrubs submit slices of metas (and/or set
        background_ops_per_flush, which meters whole requests)."""
        return self._enqueue(
            Priority.BACKGROUND, "scrub",
            len(metas) * self.codec.code.n,
            lambda: self._plan_scrub(metas, reader_cluster),
            tenant=tenant, admitted=_admitted)

    def submit_checkpoint_write(self, buf: bytes, start_stripe: int, *,
                                window_stripes: int | None = None,
                                tenant: str | None = None,
                                _admitted: bool = False) -> RequestHandle:
        """Checkpoint write riding BACKGROUND class: the fused
        encode+put streaming pipeline (`StripeCodec.write_stream`) runs
        in the finish phase of its class flush — it drives its own
        double-buffered kernel launches through `encode_stream`, not the
        engine op queue, so the plan phase submits nothing. Result is
        the StripeMeta list. Size (metering/admission unit) is the
        stripes-to-write times n, the blocks the write will land."""
        k, bs = self.codec.code.k, self.codec.block_size
        nstripes = max(1, -(-len(buf) // (k * bs)))
        return self._enqueue(
            Priority.BACKGROUND, "checkpoint_write",
            nstripes * self.codec.code.n,
            lambda: (lambda: self.codec.write_stream(
                buf, start_stripe=start_stripe,
                window_stripes=window_stripes)),
            tenant=tenant, admitted=_admitted)

    # -- scrub planner -------------------------------------------------------
    def _plan_scrub(self, metas, reader_cluster: int | None):
        codec = self.codec
        n, k = codec.code.n, codec.code.k
        handles: dict[int, list] = {}
        skipped = 0
        for meta in metas:
            sid = meta.stripe_id
            if all(codec.store.available(sid, b) for b in range(n)):
                handles[sid] = [codec.engine.submit_read(
                    sid, b, reader_cluster=reader_cluster)
                    for b in range(n)]
            else:
                skipped += 1        # degraded: repair's job, not scrub's

        def finish() -> ScrubReport:
            mismatched: list[tuple[int, int]] = []
            sids = sorted(handles)
            # Re-encode in max_batch_stripes chunks so the numpy staging
            # + encode launch obey the engine's per-batch ceiling. The
            # flush's resolved read payloads still scale with the scrub's
            # total bytes — bound THAT by submitting large scrubs in
            # slices, or set background_ops_per_flush so the metering
            # spreads them across cycles.
            step = codec.max_batch_stripes
            for i0 in range(0, len(sids), step):
                chunk = sids[i0:i0 + step]
                stored = {sid: [np.frombuffer(h.result(), np.uint8)
                                for h in handles[sid]] for sid in chunk}
                data = np.stack([np.stack(stored[sid][:k])
                                 for sid in chunk])
                expect = codec.backend.encode_many(codec.code, data)
                for i, sid in enumerate(chunk):
                    for b in range(k, n):
                        if not np.array_equal(expect[i, b],
                                              stored[sid][b]):
                            mismatched.append((sid, b))
            return ScrubReport(stripes=len(metas), checked=len(handles),
                               skipped=skipped,
                               mismatched=tuple(mismatched))
        return finish

    # -- flush ---------------------------------------------------------------
    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def _take(self, priority: Priority) -> list[_Request]:
        with self._lock:
            queue = self._queues[priority]
            if priority is not Priority.BACKGROUND \
                    or self.background_ops_per_flush is None:
                self._queues[priority] = []
                return queue
            take, size = [], 0
            while queue and (not take
                             or size + queue[0].handle.size
                             <= self.background_ops_per_flush):
                req = queue.pop(0)
                take.append(req)
                size += req.handle.size
            return take

    def flush(self) -> int:
        """One cycle: serve every class in priority order (client reads
        first, background last and metered). Returns requests served."""
        served = 0
        for priority in Priority:
            batch = self._take(priority)
            if not batch:
                continue
            served += len(batch)
            # Plan + execute + finish under thread-local attribution
            # scopes: the scrub finish phase launches encode kernels, so
            # the scope must cover the finishes too, not just the engine
            # flush. Outcomes are held back and resolved only after the
            # service model has advanced the clock, so handle latencies
            # include the modeled service time of their own flush.
            outcomes: list[tuple[_Request, object, BaseException | None]] = []
            with kernel_ops.launch_scope() as scope, \
                    self.codec.store.traffic.scoped() as tdelta:
                finishes: list[tuple[_Request, Callable | None,
                                     BaseException | None]] = []
                for req in batch:
                    try:
                        finishes.append((req, req.plan(), None))
                    except Exception as exc:
                        finishes.append((req, None, exc))
                self.codec.engine.flush(analyze=self.analyze_flushes)
                if self.analyze_flushes:
                    self.hazard_checked_flushes += 1
                for req, finish, exc in finishes:
                    if finish is None:
                        outcomes.append((req, None, exc))
                        continue
                    try:
                        outcomes.append((req, finish(), None))
                    except Exception as exc2:
                        outcomes.append((req, None, exc2))
            if self.service_model is not None:
                sample = ServiceSample(
                    priority=priority, requests=len(batch),
                    blocks=sum(req.handle.size for req in batch),
                    launches=scope.total, inner_bytes=tdelta.inner_bytes,
                    cross_bytes=tdelta.cross_bytes,
                    aggregated_bytes=tdelta.aggregated_bytes)
                self.clock.advance(self.service_model(sample))
            deadline = self.deadline_s.get(priority)
            with self._lock:
                cls = self.stats[priority]
                cls.flushes += 1
                for req, value, exc in outcomes:
                    req.handle._resolve(value, exc)
                    cls.requests += 1
                    cls.blocks += req.handle.size
                    if exc is not None:
                        cls.failed_requests += 1
                    cls.total_latency_s += req.handle.latency_s
                    cls.max_latency_s = max(cls.max_latency_s,
                                            req.handle.latency_s)
                    if deadline is not None \
                            and req.handle.latency_s > deadline:
                        cls.deadline_misses += 1
                cls.launches += scope.total
                cls.inner_bytes += tdelta.inner_bytes
                cls.cross_bytes += tdelta.cross_bytes
                cls.aggregated_bytes += tdelta.aggregated_bytes
        return served

    def drain(self) -> int:
        """Flush cycles until every queue is empty (background metering
        spreads a storm over several cycles). Returns requests served."""
        served = 0
        while self.pending:
            served += self.flush()
        return served

    # -- repair-scheduler convenience ---------------------------------------
    def rebuild(self, pairs: list[tuple[int, int]], *,
                reader_cluster: int | None = None,
                exclude_node: int = -1,
                priority: Priority = Priority.BACKGROUND):
        """Submit one rebuild request and drain it immediately, returning
        the same `RepairReport` the codec's synchronous path produces —
        the hook `sim/repair.py`'s data-path mode drives. The scopes are
        thread-local, so the deltas stay exact even when other shards
        flush concurrently; concurrent requests on THIS shard fold into
        the drain window, as before."""
        from repro.ckpt.stripe import RepairReport
        requested = len(dict.fromkeys(pairs))
        with kernel_ops.launch_scope() as scope, \
                self.codec.store.traffic.scoped() as tdelta:
            handle = self.submit_rebuild(pairs,
                                         reader_cluster=reader_cluster,
                                         exclude_node=exclude_node,
                                         priority=priority)
            self.drain()
            placed, stats = handle.result()
        return RepairReport(
            requested=requested, placed=placed,
            launches=scope.total,
            inner_bytes=tdelta.inner_bytes,
            cross_bytes=tdelta.cross_bytes,
            plan_groups=stats.plan_groups, patterns=stats.pattern_groups,
            multi_pairs=stats.multi_pairs,
            aggregated_bytes=tdelta.aggregated_bytes)


class ShardedFrontend:
    """Pipelined multi-shard serving layer: `num_shards` RequestFrontend
    shards, stripe ownership `stripe % num_shards`, flushed in parallel
    on a worker pool. Admission and the hot-block cache are shared;
    `stats` is the cross-shard merge. Each shard plans and flushes on
    its own `StripeCodec.clone()` (fresh engine queue, shared store and
    stripe metadata), so kernels batch per shard while shards overlap.

    `clock_factory(shard_index) -> clock` gives each shard its own
    timeline — under the saturation benchmark's `VirtualClock`s, shard
    service times accrue independently, which is exactly the parallelism
    the wall clock would show on real hardware, minus the noise."""

    def __init__(self, codec, *, num_shards: int = 1,
                 background_ops_per_flush: int | None = None,
                 cache: HotBlockCache | None = None,
                 admission: AdmissionController | None = None,
                 clock: Callable[[], float] | None = None,
                 clock_factory: Callable[[int], Callable[[], float]] | None
                 = None,
                 service_model: Callable[[ServiceSample], float] | None
                 = None,
                 deadline_s: dict[Priority, float] | None = None,
                 analyze_flushes: bool = False):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.codec = codec
        self.num_shards = num_shards
        self.cache = cache
        self.admission = admission
        codecs = [codec] + [codec.clone() for _ in range(num_shards - 1)]
        if clock_factory is not None:
            self.clocks = [clock_factory(i) for i in range(num_shards)]
        else:
            self.clocks = [clock or time.perf_counter] * num_shards
        self.shards = [
            RequestFrontend(
                codecs[i],
                background_ops_per_flush=background_ops_per_flush,
                clock=self.clocks[i], cache=cache, admission=admission,
                admission_pending=lambda: self.pending,
                service_model=service_model, deadline_s=deadline_s,
                analyze_flushes=analyze_flushes)
            for i in range(num_shards)]
        # Merged-submission sheds (rebuild/scrub rejected before any
        # shard saw them) are accounted here; `stats` folds them in.
        self._shed_stats = {p: ClassStats() for p in Priority}
        self._shed_lock = threading.Lock()
        self._pool = (ThreadPoolExecutor(
            max_workers=num_shards,
            thread_name_prefix="shard-flush")
            if num_shards > 1 else None)

    # -- routing -------------------------------------------------------------
    def shard_of(self, stripe: int) -> RequestFrontend:
        return self.shards[stripe % self.num_shards]

    def submit_client_read(self, meta, *,
                           reader_cluster: int | None = None,
                           tenant: str | None = None) -> RequestHandle:
        return self.shard_of(meta.stripe_id).submit_client_read(
            meta, reader_cluster=reader_cluster, tenant=tenant)

    def submit_degraded_read(self, meta, block: int, *,
                             reader_cluster: int | None = None,
                             tenant: str | None = None) -> RequestHandle:
        return self.shard_of(meta.stripe_id).submit_degraded_read(
            meta, block, reader_cluster=reader_cluster, tenant=tenant)

    def _admit_merged(self, priority: Priority, kind: str, size: int,
                      tenant: str | None):
        """Admission for a multi-stripe submission, charged ONCE here —
        the per-shard children bypass shard admission, so a split
        request can never be half-shed."""
        if self.admission is None:
            return None
        reason = self.admission.admit(priority, size,
                                      pending=self.pending, tenant=tenant)
        if reason is None:
            return None
        handle = RequestHandle(priority, kind, size)
        handle._resolve(None, RequestShed(reason, priority, tenant))
        with self._shed_lock:
            self._shed_stats[priority].shed_requests += 1
        return handle

    def submit_rebuild(self, pairs: list[tuple[int, int]], *,
                       reader_cluster: int | None = None,
                       exclude_node: int = -1,
                       priority: Priority = Priority.BACKGROUND,
                       tenant: str | None = None):
        """Rebuild across stripe ownership: pairs split by shard, one
        child rebuild each, merged (placed, RecoveryStats) result."""
        pairs = list(dict.fromkeys(pairs))
        priority = Priority(priority)
        shed = self._admit_merged(priority, "rebuild", len(pairs), tenant)
        if shed is not None:
            return shed
        by_shard: dict[int, list[tuple[int, int]]] = {}
        for s, b in pairs:
            by_shard.setdefault(s % self.num_shards, []).append((s, b))
        children = [
            self.shards[i].submit_rebuild(
                chunk, reader_cluster=reader_cluster,
                exclude_node=exclude_node, priority=priority,
                _admitted=True)
            for i, chunk in sorted(by_shard.items())]
        if len(children) == 1:
            return children[0]

        def combine(values):
            from repro.ckpt.stripe import RecoveryStats
            placed = sum(v[0] for v in values)
            stats = RecoveryStats(
                fast_groups=sum(v[1].fast_groups for v in values),
                pattern_groups=sum(v[1].pattern_groups for v in values),
                fast_pairs=sum(v[1].fast_pairs for v in values),
                multi_pairs=sum(v[1].multi_pairs for v in values))
            return placed, stats
        return MergedHandle(priority, "rebuild", len(pairs), children,
                            combine)

    def submit_scrub(self, metas, *,
                     reader_cluster: int | None = None,
                     tenant: str | None = None):
        metas = list(metas)
        size = len(metas) * self.codec.code.n
        shed = self._admit_merged(Priority.BACKGROUND, "scrub", size,
                                  tenant)
        if shed is not None:
            return shed
        by_shard: dict[int, list] = {}
        for meta in metas:
            by_shard.setdefault(meta.stripe_id % self.num_shards,
                                []).append(meta)
        children = [
            self.shards[i].submit_scrub(
                chunk, reader_cluster=reader_cluster, _admitted=True)
            for i, chunk in sorted(by_shard.items())]
        if len(children) == 1:
            return children[0]

        def combine(values):
            mismatched: list[tuple[int, int]] = []
            for v in values:
                mismatched.extend(v.mismatched)
            return ScrubReport(
                stripes=sum(v.stripes for v in values),
                checked=sum(v.checked for v in values),
                skipped=sum(v.skipped for v in values),
                mismatched=tuple(sorted(mismatched)))
        return MergedHandle(Priority.BACKGROUND, "scrub", size, children,
                            combine)

    def submit_checkpoint_write(self, buf: bytes, start_stripe: int, *,
                                window_stripes: int | None = None,
                                tenant: str | None = None):
        """Checkpoint write routed whole to the shard owning
        `start_stripe`: the streamed write is one fused pipeline over
        consecutive stripes (splitting it would serialize the double
        buffer), and stripe metadata is shared across clones so every
        shard sees the landed stripes. Admission is charged once here,
        like other multi-stripe submissions."""
        k, bs = self.codec.code.k, self.codec.block_size
        nstripes = max(1, -(-len(buf) // (k * bs)))
        size = nstripes * self.codec.code.n
        shed = self._admit_merged(Priority.BACKGROUND, "checkpoint_write",
                                  size, tenant)
        if shed is not None:
            return shed
        return self.shard_of(start_stripe).submit_checkpoint_write(
            buf, start_stripe, window_stripes=window_stripes,
            tenant=tenant, _admitted=True)

    # -- flush ---------------------------------------------------------------
    @property
    def pending(self) -> int:
        return sum(shard.pending for shard in self.shards)

    def flush(self) -> int:
        """One cycle on every shard — in parallel on the worker pool when
        num_shards > 1. Per-shard flushes keep the class order (client
        reads first, metered background last) independently; cross-shard
        they overlap, which is the pipeline."""
        if self._pool is None:
            return self.shards[0].flush()
        futures = [self._pool.submit(shard.flush)
                   for shard in self.shards]
        return sum(f.result() for f in futures)

    def drain(self) -> int:
        served = 0
        while self.pending:
            served += self.flush()
        return served

    # -- accounting ----------------------------------------------------------
    @property
    def stats(self) -> dict[Priority, ClassStats]:
        """Cross-shard ClassStats merge (plus merged-submission sheds)."""
        with self._shed_lock:
            return merge_class_stats(
                [shard.stats for shard in self.shards]
                + [self._shed_stats])

    @property
    def hazard_checked_flushes(self) -> int:
        return sum(shard.hazard_checked_flushes for shard in self.shards)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "ShardedFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
