"""Request-level I/O layer: backend abstraction, coalescing op engine
(with gateway XOR pre-folds), priority-classed shard-parallel front-end
with admission control, a degraded-read hot-block cache, and the
Zipf/virtual-time workload machinery that drives it at saturation. Sits
between the kernels and the stripe planner:
topo → core → kernels → io → ckpt → launch."""
from .backend import (BACKENDS, Backend, KernelBackend, NumpyBackend,
                      resolve_backend)
from .cache import CacheStats, HotBlockCache
from .engine import CodingEngine, FlushStats, OpHandle
# Priority/ClassStats canonically live in repro.priority; re-exported
# here because the io layer is where most consumers meet them.
from .frontend import (ClassStats, MergedHandle, Priority, RequestFrontend,
                       RequestHandle, RequestShed, ScrubReport,
                       ServiceSample, ShardedFrontend)
from .workload import (Arrival, CompletedRequest, ServiceModel,
                       VirtualClock, ZipfWorkload, drive_open_loop)

__all__ = ["BACKENDS", "Backend", "KernelBackend", "NumpyBackend",
           "resolve_backend",
           "CacheStats", "HotBlockCache",
           "CodingEngine", "FlushStats", "OpHandle",
           "ClassStats", "MergedHandle", "Priority", "RequestFrontend",
           "RequestHandle", "RequestShed", "ScrubReport", "ServiceSample",
           "ShardedFrontend",
           "Arrival", "CompletedRequest", "ServiceModel", "VirtualClock",
           "ZipfWorkload", "drive_open_loop"]
