"""Request-level I/O layer: backend abstraction, coalescing op engine
(with gateway XOR pre-folds), priority-classed front-end with per-link-
tier byte accounting. Sits between the kernels and the stripe planner:
topo → core → kernels → io → ckpt → launch."""
from .backend import (BACKENDS, Backend, KernelBackend, NumpyBackend,
                      resolve_backend)
from .engine import CodingEngine, FlushStats, OpHandle
# Priority/ClassStats canonically live in repro.priority; re-exported
# here because the io layer is where most consumers meet them.
from .frontend import (ClassStats, Priority, RequestFrontend, RequestHandle,
                       ScrubReport)

__all__ = ["BACKENDS", "Backend", "KernelBackend", "NumpyBackend",
           "resolve_backend",
           "CodingEngine", "FlushStats", "OpHandle",
           "ClassStats", "Priority", "RequestFrontend", "RequestHandle",
           "ScrubReport"]
