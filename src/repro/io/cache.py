"""HotBlockCache: degraded-read result cache for fan-in storms.

The serving pathology of wide stripes (paper §2.2; "Making Wide Stripes
Practical", arXiv 2512.10425): one failed node turns every read of a
hot block it held into a *decode* — and a Zipf-skewed client population
hits the same few blocks over and over, so the coding path burns
O(requests) launches reproducing the same bytes. The cache collapses
that storm to O(1) decodes per distinct block: the first degraded read
decodes and inserts; every subsequent read of the same `(stripe,
block)` is served at submit time with zero engine ops.

Correctness is delegated to the store, not to call-site discipline:
`attach(store)` registers a mutation listener (`BlockStore.
add_mutation_listener`) so EVERY content mutation — client update,
rebuild re-placement, block drop, node-wide delete — invalidates the
key the moment it happens. Byte-identity of the cached and uncached
serving paths is therefore an invariant the CI gate
(`check_regression.py --serve-*`) and the hypothesis property in
`tests/test_serving.py` can assert, not a convention.

Thread-safe: one lock around the OrderedDict (the sharded front-end
probes from every shard worker; keys are stripe-sharded but the dict is
shared). LRU order is recency-of-hit, eviction pops the coldest entry
once `capacity_blocks` is exceeded.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

__all__ = ["CacheStats", "HotBlockCache"]


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        probes = self.hits + self.misses
        return self.hits / probes if probes else 0.0


class HotBlockCache:
    """Size-bounded LRU of decoded block payloads keyed (stripe, block)."""

    def __init__(self, capacity_blocks: int = 256):
        if capacity_blocks < 1:
            raise ValueError("capacity_blocks must be >= 1")
        self.capacity_blocks = capacity_blocks
        self._entries: collections.OrderedDict[tuple[int, int], bytes] = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self._attached: set[int] = set()
        self.stats = CacheStats()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def attach(self, store) -> "HotBlockCache":
        """Subscribe to `store`'s mutation feed so writes, updates,
        drops, and rebuild re-placements invalidate eagerly. Idempotent
        per store (every shard of a front-end attaches the shared cache
        to the same store). Returns self (builder style)."""
        with self._lock:
            if id(store) in self._attached:
                return self
            self._attached.add(id(store))
        store.add_mutation_listener(self.invalidate,
                                    batch=self.invalidate_many)
        return self

    def get(self, stripe: int, block: int) -> bytes | None:
        with self._lock:
            data = self._entries.get((stripe, block))
            if data is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end((stripe, block))
            self.stats.hits += 1
            return data

    def put(self, stripe: int, block: int, data: bytes) -> None:
        key = (stripe, block)
        with self._lock:
            self._entries[key] = bytes(data)
            self._entries.move_to_end(key)
            self.stats.insertions += 1
            while len(self._entries) > self.capacity_blocks:
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def invalidate(self, stripe: int, block: int) -> None:
        with self._lock:
            if self._entries.pop((stripe, block), None) is not None:
                self.stats.invalidations += 1

    def invalidate_many(self, pairs) -> None:
        """Batched invalidation — the store's `put_many` mutation feed.
        Exactly as exact as per-pair `invalidate` (every pair is popped),
        but one lock acquisition for the whole batch instead of one per
        block of a 210-wide stripe."""
        with self._lock:
            for stripe, block in pairs:
                if self._entries.pop((stripe, block), None) is not None:
                    self.stats.invalidations += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def contains(self, stripe: int, block: int) -> bool:
        """Presence probe that does NOT touch LRU order or hit/miss
        accounting (tests and introspection)."""
        with self._lock:
            return (stripe, block) in self._entries
