"""NetworkModel: plans + placements -> per-link transfer schedules.

Two questions every consumer keeps re-answering, now answered once:

  1. *How many blocks cross a gateway* for a recovery — including the
     paper's §3.3 gateway-aggregation reading, where each remote
     cluster pre-folds its XOR-linear contribution and ships ONE block
     (so the relaxed "one group, t clusters" placement costs t−1 cross
     blocks, not |remote sources|). Aggregation is validity-checked:
     a plain-XOR gateway cannot fold Cauchy-coefficient plans or
     multi-target decodes (`plan_is_xor_linear`).
  2. *How long the transfer takes* given the link tiers — per-cluster
     gateway uplinks/downlinks, the oversubscribed core, and intra-
     cluster NICs — as a bottleneck (max-over-links) time, or as the
     Markov-calibrated serialized pipe the §5 chain assumes
     (`pipe_time` reproduces ε(N−1)B accounting exactly, so the
     closed-form MTTDL and the simulator keep agreeing on units).

Plans are duck-typed (`.sources` + `.coeffs`/`.xor_only` for a
RecoveryPlan, `.erased` + `.M` for a DecodePlan) so this module sits
*below* `repro.core` with no import cycle.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .topology import Topology


def plan_is_xor_linear(plan) -> bool:
    """True when a plain-XOR gateway can pre-fold the plan's remote
    contribution: every GF coefficient is 1 and the plan produces a
    single output block. RecoveryPlans expose `.xor_only`; DecodePlans
    qualify only with one erased target and a 0/1 coefficient row
    (a multi-target decode needs per-target GF rows at the gateway,
    which the aggregation story does not assume)."""
    coeffs = getattr(plan, "coeffs", None)
    if coeffs is not None:                          # RecoveryPlan
        return all(c == 1 for c in coeffs)
    M = getattr(plan, "M", None)
    if M is not None:                               # DecodePlan
        return (len(plan.erased) == 1
                and bool(np.all((np.asarray(M) == 0) | (np.asarray(M) == 1))))
    return False


def cross_cluster_blocks(assignment, target: int, sources, *,
                         aggregate: bool = False) -> int:
    """# block transfers crossing a gateway to repair `target`.

    aggregate=False: every remote source block ships individually.
    aggregate=True: each remote cluster ships ONE pre-folded block —
    the caller is responsible for having checked `plan_is_xor_linear`.
    """
    home = assignment[target]
    remote = [assignment[s] for s in sources if assignment[s] != home]
    return len(set(remote)) if aggregate else len(remote)


@dataclasses.dataclass
class LinkSchedule:
    """Per-link byte totals for one (or many merged) transfers.

    All cross-cluster bytes appear exactly once in `uplink` (leaving
    the source cluster's gateway), once on the core, and once in
    `down` (entering the consumer's cluster); `inner` holds bytes that
    never leave their cluster — both target-local reads and the
    gateway-local reads behind a pre-fold."""
    inner: dict[int, float] = dataclasses.field(default_factory=dict)
    uplink: dict[int, float] = dataclasses.field(default_factory=dict)
    down: dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def inner_bytes(self) -> float:
        return sum(self.inner.values())

    @property
    def cross_bytes(self) -> float:
        return sum(self.uplink.values())

    def add(self, other: "LinkSchedule", scale: float = 1.0) -> None:
        for mine, theirs in ((self.inner, other.inner),
                             (self.uplink, other.uplink),
                             (self.down, other.down)):
            for c, b in theirs.items():
                mine[c] = mine.get(c, 0.0) + b * scale

    def scaled(self, factor: float) -> "LinkSchedule":
        out = LinkSchedule()
        out.add(self, factor)
        return out


class NetworkModel:
    """Bandwidth-annotated view of a `Topology`.

    Bandwidths are in *bytes (or TB, or blocks) per time unit* — any
    consistent unit: the benchmarks build one in bytes/second from the
    topology's Gb/s links, the failure simulator in TB/hour from the
    Markov chain's ε(N−1)B pipe (`from_repair_pipe`)."""

    def __init__(self, topo: Topology, *, cross_bw: float,
                 inner_bw: float, core_bw: float | None = None):
        if cross_bw <= 0 or inner_bw <= 0:
            raise ValueError("link bandwidths must be positive")
        self.topo = topo
        self.cross_bw = float(cross_bw)
        self.inner_bw = float(inner_bw)
        self.core_bw = float(core_bw) if core_bw is not None else (
            topo.num_clusters * self.cross_bw / topo.oversubscription)

    @classmethod
    def from_topology(cls, topo: Topology) -> "NetworkModel":
        """Bytes/second from the topology's per-tier Gb/s links."""
        to_Bps = 1e9 / 8
        return cls(topo, cross_bw=topo.cross_gbps * to_Bps,
                   inner_bw=topo.inner_gbps * to_Bps,
                   core_bw=topo.core_gbps * to_Bps)

    @classmethod
    def from_repair_pipe(cls, topo: Topology, pipe_bw: float,
                         delta: float) -> "NetworkModel":
        """Markov-chain units: the §5 aggregate repair pipe ε(N−1)B
        becomes the gateway tier, inner links run 1/δ faster (δ is the
        chain's cross/inner bandwidth ratio; δ=0 means inner reads are
        free, matching C = C1 + δ·C2), and the core carries
        z·pipe/oversubscription."""
        inner = pipe_bw / delta if delta > 0 else math.inf
        return cls(topo, cross_bw=pipe_bw, inner_bw=inner,
                   core_bw=(topo.num_clusters * pipe_bw
                            / topo.oversubscription))

    # -- plan -> schedule ----------------------------------------------------
    def recovery_schedule(self, assignment, target: int, sources, *,
                          plan=None, block_bytes: float = 1.0
                          ) -> LinkSchedule:
        """Per-link bytes to rebuild `target` (consumed in its home
        cluster) from `sources`. When `plan` is XOR-linear, each remote
        cluster pre-folds its members at the gateway (their reads stay
        intra-cluster) and ships ONE block."""
        aggregate = plan is not None and plan_is_xor_linear(plan)
        home = assignment[target]
        sched = LinkSchedule()
        by_cluster: dict[int, int] = {}
        for s in sources:
            c = assignment[s]
            by_cluster[c] = by_cluster.get(c, 0) + 1
        for c, count in by_cluster.items():
            if c == home:
                sched.inner[c] = sched.inner.get(c, 0.0) + count * block_bytes
            elif aggregate and count > 1:
                sched.inner[c] = sched.inner.get(c, 0.0) + count * block_bytes
                sched.uplink[c] = sched.uplink.get(c, 0.0) + block_bytes
                sched.down[home] = sched.down.get(home, 0.0) + block_bytes
            else:
                sched.uplink[c] = (sched.uplink.get(c, 0.0)
                                   + count * block_bytes)
                sched.down[home] = (sched.down.get(home, 0.0)
                                    + count * block_bytes)
        return sched

    def recovery_blocks(self, assignment, target: int, sources, *,
                        plan=None) -> tuple[int, int]:
        """(total blocks read, cross-cluster block transfers) with the
        aggregation-validity check applied — the per-block numbers
        behind ARC/CARC and the repair ledger."""
        aggregate = plan is not None and plan_is_xor_linear(plan)
        sources = list(sources)
        return (len(sources),
                cross_cluster_blocks(assignment, target, sources,
                                     aggregate=aggregate))

    # -- schedule -> time ----------------------------------------------------
    def pipe_time(self, sched: LinkSchedule) -> float:
        """The §5 chain's serialized-pipe reading of a schedule: cross
        bytes through the ε(N−1)B gateway tier plus inner bytes at 1/δ.
        Note the chain's own C2 is ARC−CARC, which under gateway
        aggregation differs from a schedule's inner bytes (fold inputs
        read at a remote gateway count as inner here) — charging the
        exact Markov units is the caller's job via the metrics
        (`sim.RepairScheduler` does exactly that in pipe mode)."""
        return (sched.cross_bytes / self.cross_bw
                + sched.inner_bytes / self.inner_bw)

    def bottleneck(self, sched: LinkSchedule) -> tuple[float, str]:
        """(transfer time, binding link) under the per-link model: every
        tier moves in parallel and the slowest link gates the transfer.
        Terms: per-cluster intra reads + shipped-block ingest on node
        NICs, per-cluster gateway uplinks/downlinks, and the shared
        (oversubscribed) core."""
        best, label = 0.0, "idle"
        for c in set(sched.inner) | set(sched.down):
            t = (sched.inner.get(c, 0.0)
                 + sched.down.get(c, 0.0)) / self.inner_bw
            if t > best:
                best, label = t, f"ingest[{c}]"
        for c, b in sched.uplink.items():
            if b / self.cross_bw > best:
                best, label = b / self.cross_bw, f"uplink[{c}]"
        for c, b in sched.down.items():
            if b / self.cross_bw > best:
                best, label = b / self.cross_bw, f"downlink[{c}]"
        core = sched.cross_bytes / self.core_bw
        if core > best:
            best, label = core, "core"
        return best, label

    def transfer_time(self, sched: LinkSchedule) -> float:
        return self.bottleneck(sched)[0]

    # -- concurrent admission ------------------------------------------------
    def link_loads(self, sched: LinkSchedule) -> dict[tuple, float]:
        """Flatten a schedule to {link key: bytes} over the SAME link
        terms `bottleneck()` maxes over, keyed ("ingest", c) /
        ("uplink", c) / ("downlink", c) / ("core",). Invariant (pinned
        by tests): bottleneck(sched)[0] ==
        max(load / self.link_capacity(key)) over these entries — the
        flattening and the serial cost model can never disagree about
        which links a job occupies."""
        loads: dict[tuple, float] = {}
        for c in set(sched.inner) | set(sched.down):
            b = sched.inner.get(c, 0.0) + sched.down.get(c, 0.0)
            if b > 0:
                loads[("ingest", c)] = b
        for c, b in sched.uplink.items():
            if b > 0:
                loads[("uplink", c)] = b
        for c, b in sched.down.items():
            if b > 0:
                loads[("downlink", c)] = b
        cross = sched.cross_bytes
        if cross > 0:
            loads[("core",)] = cross
        return loads

    def link_capacity(self, key: tuple) -> float:
        """Bandwidth of one flattened link key (same units as the model)."""
        kind = key[0]
        if kind == "ingest":
            return self.inner_bw
        if kind in ("uplink", "downlink"):
            return self.cross_bw
        if kind == "core":
            return self.core_bw
        raise KeyError(f"unknown link key {key!r}")


#: Relative admission tolerance shared by the live ledger and the model
#: checker: a job may fill a link to exactly its capacity; the epsilon
#: only absorbs float rounding from the bytes/duration division, never
#: real oversubscription.
RESERVATION_EPS = 1e-9


def flow_rates(net: NetworkModel, sched: LinkSchedule,
               duration: float) -> dict[tuple, float]:
    """Pure reservation arithmetic: a job of `duration` moving `sched`'s
    bytes is a constant-rate flow of bytes/duration on every link it
    touches. This is THE rate computation — `LinkReservations` and the
    scheduler model checker (`repro.analysis.model`) both call it, so
    the admission semantics cannot fork."""
    if duration <= 0:
        raise ValueError("transfer duration must be positive")
    return {key: b / duration
            for key, b in net.link_loads(sched).items()}


def reservation_fits(used, rates, capacity_of, *,
                     eps: float = RESERVATION_EPS,
                     ignore_residual: bool = False) -> bool:
    """Pure admission predicate: do `rates` fit the residual capacity on
    every link, given the per-link `used` totals? Number-generic on
    purpose: the live ledger passes floats, the model checker passes
    exact `fractions.Fraction` sums (Python compares them exactly).

    `ignore_residual=True` is the deliberately BROKEN variant behind the
    model checker's counterexample tests: it checks each job in
    isolation (rate <= capacity) and ignores what is already reserved —
    the classic oversubscription bug. Never enable it outside a test.
    """
    for key, r in rates.items():
        cap = capacity_of(key)
        base = 0 if ignore_residual else used.get(key, 0)
        if base + r > cap * (1.0 + eps):
            return False
    return True


def merge_reservation(used, rates):
    """Pure reserve: a new {link: total} map with `rates` added."""
    new = dict(used)
    for key, r in rates.items():
        new[key] = new.get(key, 0) + r
    return new


def release_reservation(used, rates, capacity_of, *,
                        eps: float = RESERVATION_EPS):
    """Pure release: a new map with `rates` subtracted and float dust
    (anything at or below eps * capacity) clamped back to idle."""
    new = dict(used)
    for key, r in rates.items():
        left = new.get(key, 0) - r
        if left <= eps * capacity_of(key):
            new.pop(key, None)
        else:
            new[key] = left
    return new


class LinkReservations:
    """Fluid-flow residual-capacity ledger for concurrent transfers.

    Each admitted job runs for a fixed duration d (its *exclusive*
    bottleneck time, possibly stretched by a detection floor) and is
    modelled as a constant-rate flow: on every link it touches it
    reserves rate = bytes_on_link / d. A job is admitted only if every
    such rate fits in the link's residual capacity, so

        sum over in-flight jobs of rate(link)  <=  capacity(link)

    holds at all times — the oversubscription invariant CI gates on.
    Consequences that make this the right model for repair overlap:

      * a job whose duration IS its bottleneck transfer time reserves
        that link at full capacity — two jobs sharing a bottleneck link
        serialize, exactly like the old one-at-a-time scheduler;
      * jobs with provably disjoint link sets overlap freely;
      * a detection-limited job (duration T > transfer time) reserves
        only bytes/T on each link, so ~T/transfer such jobs overlap
        while their shared links stay at (not above) capacity.

    Release must be exact under float arithmetic, so `reserve` returns
    the rate dict and `release` subtracts those same floats (with a
    drop-to-zero clamp against residual dust).
    """

    #: Relative tolerance for admission — see `RESERVATION_EPS`.
    EPS = RESERVATION_EPS

    def __init__(self, net: NetworkModel, *,
                 unsafe_ignore_residual: bool = False):
        self.net = net
        self._used: dict[tuple, float] = {}
        self.peak_utilization = 0.0   # max over time+links of used/capacity
        self.admitted = 0
        self.rejected = 0             # admission attempts that had to wait
        # TEST-ONLY: the oversubscribing admission variant the model
        # checker's counterexample harness re-introduces on purpose.
        self.unsafe_ignore_residual = unsafe_ignore_residual

    def rates_for(self, sched: LinkSchedule,
                  duration: float) -> dict[tuple, float]:
        return flow_rates(self.net, sched, duration)

    def admits(self, rates: dict[tuple, float]) -> bool:
        """Would these per-link rates fit in the residual capacity?"""
        return reservation_fits(
            self._used, rates, self.net.link_capacity, eps=self.EPS,
            ignore_residual=self.unsafe_ignore_residual)

    def reserve(self, rates: dict[tuple, float]) -> None:
        """Commit the rates (caller already checked `admits`)."""
        self._used = merge_reservation(self._used, rates)
        for key in rates:
            cap = self.net.link_capacity(key)
            used = self._used.get(key, 0.0)
            if cap > 0 and used / cap > self.peak_utilization:
                self.peak_utilization = used / cap
        self.admitted += 1

    def release(self, rates: dict[tuple, float]) -> None:
        """Return a completed job's rates — the exact floats reserved."""
        self._used = release_reservation(self._used, rates,
                                         self.net.link_capacity,
                                         eps=self.EPS)

    def utilization(self, key: tuple) -> float:
        cap = self.net.link_capacity(key)
        return self._used.get(key, 0.0) / cap if cap > 0 else 0.0

    @property
    def busy_links(self) -> int:
        return len(self._used)
