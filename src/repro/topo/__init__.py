"""First-class cluster topology + network model.

The paper's limitation-2 claim is that existing wide LRCs ignore cluster
topology, and UniLRC's "one group, one cluster" placement wins exactly
because cross-cluster links are the scarce resource. This package makes
that resource explicit:

  * `Topology` — z clusters × nodes-per-cluster hosts plus the link
    tiers: intra-cluster node NICs, per-cluster gateway links, and a
    shared core whose capacity is the aggregate gateway bandwidth
    divided by an oversubscription factor. Subsumes the former private
    `ckpt.store.ClusterTopology` (same round-robin slot mapping), so
    store, sim, metrics, and benchmarks agree on one cluster/node model.
  * `NetworkModel` — maps a recovery/decode plan + placement to a
    per-link `LinkSchedule` and a bottleneck transfer time, including
    gateway XOR aggregation: each remote cluster pre-folds its
    XOR-linear contribution and ships ONE block. Aggregation validity is
    checked (`plan_is_xor_linear`) — a Cauchy-coefficient plan or a
    multi-target decode cannot be folded by a plain-XOR gateway.

Layering: `topo` sits below `core` (it depends only on numpy and
duck-types plan objects), so `core.placement`/`core.metrics`, the io
engine, the ckpt store, and the failure simulator can all route their
cluster arithmetic through it without cycles.
"""
from .network import (RESERVATION_EPS, LinkReservations, LinkSchedule,
                      NetworkModel, cross_cluster_blocks, flow_rates,
                      merge_reservation, plan_is_xor_linear,
                      release_reservation, reservation_fits)
from .topology import Topology

__all__ = ["Topology", "NetworkModel", "LinkSchedule", "LinkReservations",
           "cross_cluster_blocks", "plan_is_xor_linear", "RESERVATION_EPS",
           "flow_rates", "reservation_fits", "merge_reservation",
           "release_reservation"]
