"""The cluster/node/link model every layer shares.

A deployment is z clusters × `nodes_per_cluster` hosts with three link
tiers (the paper's §4.2 testbed structure — Wondershaper-limited
gateways over a shared core):

  * intra-cluster — per-node NICs at `inner_gbps` (fast, parallel);
  * gateway       — each cluster's uplink/downlink at `cross_gbps`
                    (the scarce resource topology locality minimises);
  * core          — the shared spine carrying every cross-cluster byte;
    its capacity is the aggregate gateway bandwidth divided by the
    `oversubscription` factor, so `oversubscription=1` is a
    non-blocking fabric and 10x means ten gateways' worth of traffic
    squeezes through one gateway's worth of core.

`Topology` also owns the node-id arithmetic (the round-robin slot
mapping the checkpoint store has always used): node id =
cluster * nodes_per_cluster + slot, with slot wraparound so stripe-id
rotation spreads parity load across a cluster's hosts.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Topology:
    """z clusters × nodes_per_cluster hosts, with per-tier link speeds.

    The two positional fields are the historical `ClusterTopology`
    constructor (kept: every store/codec call site builds
    `Topology(num_clusters, nodes_per_cluster)`); the link fields
    default to the paper's testbed ratio (10 Gb/s inner, 1 Gb/s
    gateways, non-blocking core).
    """
    num_clusters: int
    nodes_per_cluster: int
    inner_gbps: float = 10.0
    cross_gbps: float = 1.0
    oversubscription: float = 1.0

    def __post_init__(self):
        if self.num_clusters < 1 or self.nodes_per_cluster < 1:
            raise ValueError("topology needs >= 1 cluster and node")
        if self.oversubscription < 1.0:
            raise ValueError("oversubscription factor is >= 1 "
                             "(1 = non-blocking core)")

    @property
    def num_nodes(self) -> int:
        return self.num_clusters * self.nodes_per_cluster

    @property
    def core_gbps(self) -> float:
        """Core capacity: aggregate gateway bandwidth / oversubscription."""
        return self.num_clusters * self.cross_gbps / self.oversubscription

    def node_of(self, cluster: int, slot: int) -> int:
        return cluster * self.nodes_per_cluster + slot % self.nodes_per_cluster

    def cluster_of(self, node: int) -> int:
        return node // self.nodes_per_cluster

    def with_oversubscription(self, factor: float) -> "Topology":
        """Same fabric, different core contention (benchmark sweeps)."""
        return dataclasses.replace(self, oversubscription=factor)
